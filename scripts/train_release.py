#!/usr/bin/env python
"""Release-training driver: produce the shipped, versioned RESPECT agent.

Runs the paper's training recipe end to end — mixed-size synthetic DAG
curriculum (|V| = ``--n-min`` .. ``--n-max``, small graphs first),
rotating over the eval grid's stage counts (``--stage-counts``, one
jitted REINFORCE step per k over ONE shared TrainState), exact-DP
oracle labels (the same contiguous-segmentation optimum
:class:`repro.eval.oracle.ExactOracle` solves, via the cached vmapped
labeler) — to a *convergence criterion*: training stops when the
held-out mean exact-match across all stage counts reaches
``--target-match``, or when it fails to improve for ``--patience``
consecutive evals, or at ``--max-steps``.

The curriculum is a TOPOLOGY MIXTURE: the paper's chain-dominated
``sample_dag`` mixture (deg(V) ∈ {2..6}) plus the eval grid's three
synthetic families (chain / layered / branchy), uniformly.  Training
only on the paper sampler leaves the policy out-of-distribution on
wide level-structured graphs — it then loses to plain list scheduling
on the large-graph generalization tier.  The eval scenarios draw from
DIFFERENT seed streams (``hash_seed`` cells), so the distributions
match but no evaluation graph is ever trained on.

The output is a **versioned release checkpoint**
(:mod:`repro.checkpoint.release`): ``<out>/release.json`` pins the
config, data seed, curriculum, git sha and the sha256 of the parameter
bytes; ``<out>/params/`` holds the weights.  ``RespectScheduler
.from_release()`` loads it by default, the goldens and ``BENCH_eval``
are pinned against it, and CI verifies its integrity on every push.

    # the shipped checkpoints/respect-v1 was produced with exactly:
    PYTHONPATH=src python scripts/train_release.py \
        --out checkpoints/respect-v1 --version respect-v1 --seed 0

Resumable: ``--ckpt-dir`` keeps trainer checkpoints + the sampler
counter; kill and re-run with the same flags to continue.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from datetime import datetime, timezone
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint.release import write_release  # noqa: E402
from repro.core import PipelineSystem  # noqa: E402
from repro.core.batching import bucket_for  # noqa: E402
from repro.core.rl import RLTrainer, pack_graphs  # noqa: E402
from repro.core.sampler import sample_dag  # noqa: E402
from repro.eval.scenarios import SYNTH_FAMILIES, synthetic_dag  # noqa: E402

# curriculum topology mixture: the paper sampler + the eval families
FAMILY_MIX = ("paper",) + SYNTH_FAMILIES


def _mixed_graphs(rng: np.random.Generator, batch: int,
                  n_spec: tuple[int, int]) -> list:
    """``batch`` graphs, each drawing its own family and size."""
    graphs = []
    for _ in range(batch):
        fam = FAMILY_MIX[int(rng.integers(len(FAMILY_MIX)))]
        n = int(rng.integers(n_spec[0], n_spec[1] + 1))
        if fam == "paper":
            graphs.append(sample_dag(rng, n=n,
                                     deg=int(rng.choice((2, 3, 4, 5, 6)))))
        else:
            graphs.append(synthetic_dag(fam, rng, n))
    return graphs


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=Path(__file__).resolve().parent, check=True,
        ).stdout.strip()
    except Exception:
        return "unknown"


def _draw(seed: int, count: int, batch: int, n_lo: int, n_hi: int,
          ramp_batches: int):
    """One deterministic curriculum draw: (seed, count) -> graphs.

    The size range ramps from [n_lo, n_lo+..] to the full [n_lo, n_hi]
    over the first ``ramp_batches`` draws — the paper's small-graphs-first
    transfer recipe — and every draw is a pure function of (seed, count),
    so a resumed run continues the identical stream.
    """
    n_spec = (n_lo, n_hi)
    if count < ramp_batches:
        frac = (count + 1) / ramp_batches
        n_spec = (n_lo, n_lo + max(1, int((n_hi - n_lo) * frac)))
    rng = np.random.default_rng((seed, count))
    return _mixed_graphs(rng, batch, n_spec)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="checkpoints/respect-v1")
    ap.add_argument("--version", default="respect-v1")
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-min", type=int, default=5)
    ap.add_argument("--n-max", type=int, default=50)
    ap.add_argument("--stage-counts", default="2,3,4,6,8",
                    help="comma list; one draw per k, round-robin")
    ap.add_argument("--ramp-batches", type=int, default=64,
                    help="curriculum: draws to widen |V| range over")
    ap.add_argument("--max-steps", type=int, default=4000)
    ap.add_argument("--eval-every", type=int, default=50,
                    help="evals are counted in DRAWS (one draw may run "
                         "several bucketed steps)")
    ap.add_argument("--target-match", type=float, default=0.98,
                    help="stop when held-out mean exact-match across all "
                         "stage counts reaches this")
    ap.add_argument("--patience", type=int, default=10,
                    help="stop after this many evals without improvement")
    ap.add_argument("--entropy-coef", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--label-cache", default="artifacts/label_cache")
    ap.add_argument("--ckpt-dir", default="artifacts/release_train_ckpt")
    ap.add_argument("--save-every", type=int, default=200)
    ap.add_argument("--devices", type=int, default=None)
    args = ap.parse_args()
    stage_counts = tuple(int(s) for s in args.stage_counts.split(","))

    base = PipelineSystem(n_stages=stage_counts[0])
    trainer = RLTrainer(system=base, hidden=args.hidden, lr=args.lr,
                        seed=args.seed, n_devices=args.devices,
                        entropy_coef=args.entropy_coef,
                        stage_counts=stage_counts)
    bucket_n = bucket_for(args.n_max)

    def pack(graphs, k):
        return pack_graphs(graphs, k, base.with_stages(k),
                           cache_dir=args.label_cache, bucket_n=bucket_n)

    # held-out eval sets: one per stage count, disjoint seed stream,
    # same topology mixture as the curriculum
    eval_batches = {}
    for k in stage_counts:
        rng = np.random.default_rng((args.seed + 10 ** 6, k))
        eval_batches[k] = pack(
            _mixed_graphs(rng, 128, (args.n_min, args.n_max)), k)

    def held_out() -> tuple[float, float]:
        rs, ms = [], []
        for k in stage_counts:
            ev = trainer.evaluate(eval_batches[k], n_stages=k)
            rs.append(ev["reward_greedy"])
            ms.append(ev["exact_match"])
        return float(np.mean(rs)), float(np.mean(ms))

    # resume
    ckpt_dir = Path(args.ckpt_dir)
    count_path = ckpt_dir / "draw_count.json"
    count = 0
    resumed = trainer.restore(args.ckpt_dir)
    if resumed is not None and count_path.exists():
        count = int(json.loads(count_path.read_text())["count"])
        print(f"[resume] trainer step {resumed}, draw count {count}")

    def save(blocking=True):
        trainer.save(args.ckpt_dir, blocking=blocking)
        count_path.write_text(json.dumps({"count": count}))

    key = jax.random.PRNGKey(args.seed)
    r0, m0 = held_out()
    print(f"[init] mean greedy reward {r0:.4f} exact-match {m0:.3f} over "
          f"k={stage_counts}")

    best_match, bad_evals, t0 = m0, 0, time.time()
    converged = None
    history = []
    while trainer.step_count < args.max_steps:
        k = stage_counts[count % len(stage_counts)]
        graphs = _draw(args.seed, count, args.batch, args.n_min, args.n_max,
                       args.ramp_batches)
        count += 1
        batch = pack(graphs, k)
        metrics = trainer.train_step(
            batch, jax.random.fold_in(key, count), n_stages=k)
        if count % 10 == 0:
            print(f"[step {trainer.step_count} draw {count} k={k}] "
                  f"reward={metrics['reward_sample']:.4f} "
                  f"baseline={metrics['reward_baseline']:.4f} "
                  f"({(time.time() - t0) / count:.2f}s/draw)", flush=True)
        if count % args.eval_every == 0:
            r, m = held_out()
            trainer.consider_baseline(r)
            history.append({"step": trainer.step_count, "draws": count,
                            "reward": r, "exact_match": m})
            improved = m > best_match + 1e-4
            bad_evals = 0 if improved else bad_evals + 1
            best_match = max(best_match, m)
            print(f"[eval step {trainer.step_count}] reward={r:.4f} "
                  f"exact-match={m:.3f} best={best_match:.3f} "
                  f"stale={bad_evals}/{args.patience}", flush=True)
            if m >= args.target_match:
                converged = f"target exact-match {args.target_match} reached"
                break
            if bad_evals >= args.patience:
                converged = f"no improvement for {args.patience} evals"
                break
        if count % args.save_every == 0:
            save(blocking=False)
    save()
    if converged is None:
        converged = f"max steps {args.max_steps} reached"

    r_final, m_final = held_out()
    print(f"[done] {converged}; mean greedy reward {r_final:.4f} "
          f"exact-match {m_final:.3f} (init {r0:.4f}/{m0:.3f})")

    from repro.core.embedding import embed_dim
    manifest = write_release(trainer.params, args.out, {
        "version": args.version,
        "created": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
        "config": {"hidden": args.hidden, "feat_dim": embed_dim(),
                   "mask_infeasible": True, "max_deg": 6},
        "train": {
            "data_seed": args.seed, "n_range": [args.n_min, args.n_max],
            "family_mix": list(FAMILY_MIX),
            "stage_counts": list(stage_counts), "batch": args.batch,
            "lr": args.lr, "label_method": "dp",
            "ramp_batches": args.ramp_batches,
            "steps": trainer.step_count, "draws": count,
            "stopped": converged,
            "command": "scripts/train_release.py "
                       + " ".join(sys.argv[1:]),
        },
        "eval": {"reward_greedy_mean": r_final, "exact_match_mean": m_final,
                 "stage_counts": list(stage_counts),
                 "history": history[-20:]},
        "system": dataclasses.asdict(base)
        if dataclasses.is_dataclass(base) else str(base),
    })
    print(f"[release] wrote {args.out} (params sha256 "
          f"{manifest['params_sha256'][:16]}...)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
