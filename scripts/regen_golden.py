#!/usr/bin/env python
"""Regenerate the Table-I golden schedule snapshot.

    PYTHONPATH=src python scripts/regen_golden.py [--out tests/golden/dnn_schedules.json]

Writes, for each of the ten Table-I ImageNet model graphs, the structure
triple (|V|, deg(V), depth) plus a schedule snapshot — sha256 digests of
the decoded order and the repaired assignment, and the evaluated
bottleneck/latency — produced by a FIXED agent (``RespectScheduler.init``
at the pinned seed/hidden below, deterministic across machines for a
given jax version) on the default Edge-TPU pipeline system.

``tests/test_dnn_golden.py`` diffs live schedules against this file, so
a decode, cost-model, rho or repair change that shifts any real-model
schedule fails loudly instead of drifting silently.  Run this script and
commit the diff ONLY when such a shift is intended and reviewed.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# the pinned golden configuration — bump deliberately, never implicitly
SEED = 0
HIDDEN = 64
N_STAGES = 4


def digest(arr) -> str:
    import numpy as np
    return hashlib.sha256(np.asarray(arr, dtype=np.int64).tobytes()).hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="tests/golden/dnn_schedules.json")
    args = ap.parse_args()

    from repro.core import (MODEL_SPECS, RespectScheduler, build_model_graph,
                            evaluate_schedule)
    from repro.core.costmodel import PipelineSystem

    sched = RespectScheduler.init(seed=SEED, hidden=HIDDEN)
    system = PipelineSystem(n_stages=N_STAGES)
    graphs = {name: build_model_graph(name) for name in MODEL_SPECS}
    results = sched.schedule_many(list(graphs.values()), N_STAGES, system,
                                  use_cache=False)

    models = {}
    for (name, g), res in zip(graphs.items(), results):
        ev = evaluate_schedule(g, res.assignment, system)
        models[name] = {
            "n": g.n,
            "deg": g.max_in_degree,
            "depth": g.depth,
            "order_sha256": digest(res["order"]),
            "assign_sha256": digest(res.assignment),
            "bottleneck_s": ev.bottleneck_s,
            "latency_s": ev.latency_s,
        }
        print(f"{name:20s} n={g.n:4d} assign={models[name]['assign_sha256'][:12]} "
              f"bottleneck={ev.bottleneck_s:.6e}")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "meta": {"seed": SEED, "hidden": HIDDEN, "n_stages": N_STAGES,
                 "system": "PipelineSystem(n_stages=4) defaults"},
        "models": models,
    }, indent=1) + "\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
