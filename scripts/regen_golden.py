#!/usr/bin/env python
"""Regenerate the Table-I golden schedule snapshot.

    PYTHONPATH=src python scripts/regen_golden.py [--out tests/golden/dnn_schedules.json]

Writes, for each of the ten Table-I ImageNet model graphs, the structure
triple (|V|, deg(V), depth) plus a schedule snapshot — sha256 digests of
the decoded order and the repaired assignment, and the evaluated
bottleneck/latency — produced by the TRAINED release agent
(``RespectScheduler.from_release()``: the newest integrity-verified
``checkpoints/respect-v*``, whose parameter sha256 the golden meta pins,
so the snapshot can never silently drift to a different agent) on the
default Edge-TPU pipeline system, AND the gap-to-optimal record against
the exact solver: the optimal assignment digest and bottleneck (batched
device oracle, parity-asserted against the host ``exact_dp`` at regen
time), the agent's optimality gap and whether it matches the optimum.

``tests/test_dnn_golden.py`` diffs live schedules against this file — and
re-renders the whole payload in-process to assert it round-trips
BYTE-identically — so a decode, cost-model, rho, repair or exact-solver
change that shifts any real-model schedule or gap fails loudly instead
of drifting silently.  Run this script and commit the diff ONLY when
such a shift is intended and reviewed.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# the pinned golden configuration — bump deliberately, never implicitly
N_STAGES = 4


def digest(arr) -> str:
    import numpy as np
    return hashlib.sha256(np.asarray(arr, dtype=np.int64).tobytes()).hexdigest()


def build_payload() -> dict:
    """The full golden payload, computed from the pinned configuration.

    Pure function of the code + pinned constants: the round-trip test
    re-runs it in-process and compares bytes against the checked-in file.
    """
    import numpy as np

    from repro.core import (MODEL_SPECS, RespectScheduler, build_model_graph,
                            evaluate_schedule)
    from repro.core.costmodel import PipelineSystem
    from repro.eval import ExactOracle

    sched = RespectScheduler.from_release()
    if sched.release is None:
        raise SystemExit(
            "regen_golden: no trained release checkpoint found — the "
            "golden snapshot is pinned against checkpoints/respect-v*; "
            "train one with scripts/train_release.py first")
    system = PipelineSystem(n_stages=N_STAGES)
    graphs = {name: build_model_graph(name) for name in MODEL_SPECS}
    results = sched.schedule_many(list(graphs.values()), N_STAGES, system,
                                  use_cache=False)
    oracle = ExactOracle()
    opts = oracle.solve_many(list(graphs.values()), N_STAGES, system)
    hosts = ExactOracle.solve_many_host(list(graphs.values()), N_STAGES,
                                        system)
    for name, o, h in zip(graphs, opts, hosts):
        assert np.array_equal(o.assignment, h.assignment), (
            f"{name}: device oracle diverged from host exact_dp at regen "
            "time — fix the solver before re-pinning")

    models = {}
    for (name, g), res, opt in zip(graphs.items(), results, opts):
        ev = evaluate_schedule(g, res.assignment, system)
        gap = ev.bottleneck_s / opt.bottleneck_s - 1.0
        models[name] = {
            "n": g.n,
            "deg": g.max_in_degree,
            "depth": g.depth,
            "order_sha256": digest(res["order"]),
            "assign_sha256": digest(res.assignment),
            "bottleneck_s": ev.bottleneck_s,
            "latency_s": ev.latency_s,
            "opt_assign_sha256": digest(opt.assignment),
            "opt_bottleneck_s": opt.bottleneck_s,
            "opt_latency_s": opt.latency_s,
            "gap_to_optimal": gap,
            "matches_optimal": bool(gap <= 1e-9),
        }

    return {
        "meta": {"agent": "release",
                 "release_version": sched.release["version"],
                 "params_sha256": sched.release["params_sha256"],
                 "n_stages": N_STAGES,
                 "system": "PipelineSystem(n_stages=4) defaults"},
        "models": models,
    }


def render(payload: dict) -> str:
    """The exact on-disk serialization (the round-trip contract)."""
    return json.dumps(payload, indent=1) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="tests/golden/dnn_schedules.json")
    args = ap.parse_args()

    payload = build_payload()
    for name, m in payload["models"].items():
        print(f"{name:20s} n={m['n']:4d} assign={m['assign_sha256'][:12]} "
              f"bottleneck={m['bottleneck_s']:.6e} "
              f"gap={m['gap_to_optimal']*100:.2f}% "
              f"match={m['matches_optimal']}")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(render(payload))
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
