#!/usr/bin/env python
"""Deployment-flow simulation for all ten ImageNet models (paper §IV).

For every Table-I model and every pipeline depth in {4, 5, 6}: schedule with
the commercial-compiler emulation, the exact solver and RESPECT; validate
deployability (monotone + repaired); and simulate steady-state pipeline
throughput on the Coral cost model.  This mirrors the paper's physical
evaluation loop with the simulator standing in for the USB-chained boards.

    PYTHONPATH=src python examples/edge_pipeline_deploy.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import (EDGETPU, MODEL_SPECS, RespectScheduler,  # noqa: E402
                        build_model_graph, compiler_partition,
                        evaluate_schedule, exact_dp, validate_monotone)


def main() -> int:
    agent_path = Path("artifacts/respect_agent.npz")
    sched = (RespectScheduler.load(agent_path) if agent_path.exists()
             else RespectScheduler.init(seed=0))
    print(f"agent: {'trained' if agent_path.exists() else 'untrained'}\n")

    print(f"{'model':20s} {'k':>2s} {'compiler':>9s} {'exact':>9s} "
          f"{'RESPECT':>9s} {'RL-speedup':>10s}")
    speedups = []
    for name in MODEL_SPECS:
        g = build_model_graph(name)
        for k in (4, 5, 6):
            sys_ = EDGETPU.with_stages(k)
            ev_c = evaluate_schedule(g, compiler_partition(g, k, sys_), sys_)
            a_e, _ = exact_dp(g, k, sys_)
            ev_e = evaluate_schedule(g, a_e, sys_)
            res = sched.schedule(g, k, sys_)
            assert validate_monotone(g, res.assignment, k)
            ev_r = evaluate_schedule(g, res.assignment, sys_)
            sp = ev_c.bottleneck_s / ev_r.bottleneck_s
            speedups.append(sp)
            print(f"{name:20s} {k:2d} {ev_c.bottleneck_s*1e3:8.3f}m "
                  f"{ev_e.bottleneck_s*1e3:8.3f}m {ev_r.bottleneck_s*1e3:8.3f}m "
                  f"{sp:9.2f}x")
    print(f"\nmean RESPECT speedup over compiler emulation: "
          f"{np.mean(speedups):.2f}x (max {np.max(speedups):.2f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
