"""Serve scheduling traffic through the async front end.

Spins up a :class:`repro.serving.SchedulerService` over a scheduler,
AOT-warms the bucket shapes the traffic will hit, replays a bursty
mixed-size request stream (synthetic DAGs plus a Table-I model), and
prints the rolling service metrics.

    PYTHONPATH=src python examples/serve_traffic.py [--requests 80]
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import RespectScheduler, build_model_graph, sample_dag  # noqa: E402
from repro.serving import SchedulerService  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=80)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=3.0)
    ap.add_argument("--stages", type=int, default=4)
    args = ap.parse_args()

    sched = RespectScheduler.init(seed=0, hidden=args.hidden,
                                  max_compiled=64)
    rng = np.random.default_rng(0)
    pool = [sample_dag(rng, n=int(rng.integers(10, 33)), deg=3)
            for _ in range(8)]
    pool.append(build_model_graph("ResNet50"))

    with SchedulerService(sched, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms) as svc:
        print("warming expected bucket shapes ...")
        t0 = time.perf_counter()
        svc.warmup(pool, n_stages=args.stages)
        print(f"  warm in {time.perf_counter() - t0:.1f}s "
              f"({len(sched._decoder.compiled_shapes)} programs)")

        def burst(tag: str):
            t0 = time.perf_counter()
            futs = [svc.submit(pool[int(rng.integers(0, len(pool)))],
                               args.stages)
                    for _ in range(args.requests)]
            out = [f.result(timeout=300) for f in futs]
            dt = time.perf_counter() - t0
            print(f"  {tag}: {len(out)} schedules in {dt:.2f}s "
                  f"({len(out) / dt:.1f} graphs/s)")
            return out, dt

        print(f"replaying two bursts of {args.requests} requests "
              f"(pool of {len(pool)} graphs) ...")
        burst("burst 1 (cold: misses + batch-shape compiles)")
        results, dt = burst("burst 2 (warm: schedule cache + dedup)")
        st = svc.stats()

    print(f"  rolling latency p50={st.p50_ms:.2f}ms p99={st.p99_ms:.2f}ms")
    print(f"  batches={st.batches} (largest {st.max_batch_observed}); "
          f"hits={st.cache_hits} misses={st.cache_misses} "
          f"dedups={st.dedup_hits}")
    r = results[-1]
    print(f"  last result: model={r['model']} stages -> "
          f"{np.bincount(r.assignment, minlength=args.stages).tolist()} "
          f"nodes per stage")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
