#!/usr/bin/env python
"""End-to-end driver: train the RESPECT agent with REINFORCE (paper §III-B).

The paper's pipeline on the unified padded batch stack: synthetic DAG
sampler (fixed |V| = 30 or a mixed-size range) -> exact labels (vmapped DP,
on-disk cache) -> LSTM-PtrNet + rollout-baseline REINFORCE -> deployable
scheduler checkpoint.  Training consumes the SAME pad-aware
`PaddedGraphBatch` representation the serving engine runs on, so mixed-size
curriculum streams, data-parallel sharding and checkpoint resume all ride
the one batch contract.

Defaults are scaled for this single-CPU-core container (hidden 128,
batch 64, a few hundred steps — minutes); ``--paper-scale`` selects the
paper's setup (hidden 256, batch 128, lr 1e-4 Adam).

    PYTHONPATH=src python examples/train_respect.py --steps 300
    # mixed-size curriculum (transfers to larger real DNN graphs):
    PYTHONPATH=src python examples/train_respect.py --n-min 10 --n-max 50
    # data parallel over forced host devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/train_respect.py --devices 8

Outputs: artifacts/respect_agent (checkpoint-manager format, used by
benchmarks/) + metrics JSONL + periodic trainer checkpoints under
--ckpt-dir (resumable: kill and re-run to continue).
"""

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.core import DagSampler, PipelineSystem, RespectScheduler, prefetch  # noqa: E402
from repro.core.rl import RLTrainer  # noqa: E402
from repro.runtime.metrics import MetricsLogger  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--n-min", type=int, default=30,
                    help="smallest sampled graph size")
    ap.add_argument("--n-max", type=int, default=30,
                    help="largest sampled graph size (n-min < n-max turns "
                         "on the mixed-size curriculum stream)")
    ap.add_argument("--no-curriculum", action="store_true",
                    help="mixed sizes without the small-first ramp")
    ap.add_argument("--devices", type=int, default=None,
                    help="data-parallel device count (shard_map over the "
                         "batch axis; global batch must divide it)")
    ap.add_argument("--label-method", choices=("dp", "bb"), default="dp")
    ap.add_argument("--label-cache", default="artifacts/label_cache")
    ap.add_argument("--ckpt-dir", default="artifacts/respect_ckpt")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--paper-scale", action="store_true",
                    help="hidden 256, batch 128, lr 1e-4 (paper setup)")
    ap.add_argument("--out", default="artifacts/respect_agent")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.paper_scale:
        args.hidden, args.batch, args.lr = 256, 128, 1e-4

    system = PipelineSystem(n_stages=args.stages)
    n_spec = (args.n_min, args.n_max) if args.n_min < args.n_max else args.n_min
    sampler = DagSampler(seed=args.seed, n=n_spec,
                         label_cache_dir=args.label_cache)
    eval_sampler = DagSampler(seed=args.seed + 10**6, n=n_spec,
                              label_cache_dir=args.label_cache)
    eval_batch = eval_sampler.next_packed_batch(
        128, args.stages, system, label_method=args.label_method)

    trainer = RLTrainer(n_stages=args.stages, system=system,
                        hidden=args.hidden, lr=args.lr, seed=args.seed,
                        n_devices=args.devices)
    sampler_state_path = Path(args.ckpt_dir) / "sampler_state.json"

    def save_all(blocking: bool = True) -> None:
        trainer.save(args.ckpt_dir, blocking=blocking)
        # the prefetch thread may have drawn up to `depth` batches ahead of
        # the trainer, so a resume continues from the saved counter: it
        # never REPLAYS consumed data (the failure that degrades training),
        # at worst it skips the few prefetched-but-unconsumed draws.
        sampler_state_path.write_text(json.dumps(sampler.state()))

    resumed = trainer.restore(args.ckpt_dir)
    if resumed is not None:
        if sampler_state_path.exists():
            sampler.restore(json.loads(sampler_state_path.read_text()))
        print(f"[resume] restored trainer checkpoint at step {resumed} "
              f"(sampler counter {sampler.state()['count']})")
    logger = MetricsLogger("artifacts/respect_train_metrics.jsonl",
                           print_every=10)
    key = jax.random.PRNGKey(args.seed)

    r0 = trainer.evaluate(eval_batch)
    print(f"[init] greedy reward {r0['reward_greedy']:.4f} "
          f"exact-match {r0['exact_match']:.3f}")

    # labeled per-bucket packs stream from a background thread while the
    # device runs the current step; batch dims stay divisible by the
    # device count, and the restored (seed, counter) state makes a
    # resumed stream continue exactly where the killed run stopped
    stream = prefetch(sampler.packed_stream(
        args.batch, args.stages, system, label_method=args.label_method,
        curriculum=not args.no_curriculum,
        batch_divisor=args.devices or 1), depth=2)

    t0 = time.time()
    step = trainer.step_count
    while step < args.steps:
        batch = next(stream)
        # per-step key by fold_in: resuming at step k reproduces the key
        # stream a never-interrupted run would have used
        k = jax.random.fold_in(key, step)
        metrics = trainer.train_step(batch, k)
        step = trainer.step_count
        logger.log(step, metrics)
        if step % args.eval_every == 0:
            updated = trainer.maybe_update_baseline(eval_batch)
            ev = trainer.evaluate(eval_batch)
            print(f"[eval step {step}] greedy={ev['reward_greedy']:.4f} "
                  f"exact-match={ev['exact_match']:.3f} "
                  f"baseline-updated={updated} "
                  f"({(time.time()-t0)/max(step,1):.2f}s/step)")
        if step % args.save_every == 0:
            save_all(blocking=False)

    save_all()
    ev = trainer.evaluate(eval_batch)
    print(f"[final] greedy reward {ev['reward_greedy']:.4f} "
          f"(start {r0['reward_greedy']:.4f}) "
          f"exact-match {ev['exact_match']:.3f}")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    RespectScheduler(trainer.params).save(out)
    print(f"[saved] {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
