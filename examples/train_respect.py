#!/usr/bin/env python
"""End-to-end driver: train the RESPECT agent with REINFORCE (paper §III-B).

This is the paper's training pipeline: synthetic DAG sampler -> exact labels
(branch-and-bound) -> LSTM-PtrNet + rollout-baseline REINFORCE -> deployable
scheduler checkpoint.  Defaults are scaled for this single-CPU-core container
(hidden 128, batch 64, a few hundred steps — minutes); ``--paper-scale``
selects the paper's setup (hidden 256, batch 128, 1M-graph stream,
lr 1e-4 Adam), which is what you would run on the paper's 2080 Ti.

    PYTHONPATH=src python examples/train_respect.py --steps 300

Outputs: artifacts/respect_agent.npz (used by benchmarks/) + metrics JSONL +
periodic checkpoints (resumable: kill and re-run to continue).
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import PipelineSystem, RespectScheduler  # noqa: E402
from repro.core.rl import RLTrainer  # noqa: E402
from repro.data import LabeledDagDataset  # noqa: E402
from repro.runtime.metrics import MetricsLogger  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--dataset-size", type=int, default=2048)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--paper-scale", action="store_true",
                    help="hidden 256, batch 128, lr 1e-4 (paper setup)")
    ap.add_argument("--out", default="artifacts/respect_agent.npz")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.paper_scale:
        args.hidden, args.batch, args.lr = 256, 128, 1e-4

    system = PipelineSystem(n_stages=args.stages)
    print(f"[data] building labeled dataset ({args.dataset_size} graphs, "
          f"exact branch-and-bound labels) ...")
    t0 = time.time()
    ds = LabeledDagDataset(count=args.dataset_size, n_stages=args.stages,
                           seed=args.seed, label_method="bb",
                           system=system)
    ds.build(verbose=True)
    eval_batch = ds.batch(10**6, 128)
    print(f"[data] ready in {time.time()-t0:.1f}s")

    trainer = RLTrainer(n_stages=args.stages, system=system,
                        hidden=args.hidden, lr=args.lr, seed=args.seed)
    logger = MetricsLogger("artifacts/respect_train_metrics.jsonl",
                           print_every=10)
    key = jax.random.PRNGKey(args.seed)

    r0 = trainer.evaluate(eval_batch)
    print(f"[init] greedy reward {r0['reward_greedy']:.4f} "
          f"exact-match {r0['exact_match']:.3f}")

    t0 = time.time()
    for step in range(1, args.steps + 1):
        key, k = jax.random.split(key)
        metrics = trainer.train_step(ds.batch(step, args.batch), k)
        logger.log(step, metrics)
        if step % args.eval_every == 0:
            updated = trainer.maybe_update_baseline(eval_batch)
            ev = trainer.evaluate(eval_batch)
            print(f"[eval step {step}] greedy={ev['reward_greedy']:.4f} "
                  f"exact-match={ev['exact_match']:.3f} "
                  f"baseline-updated={updated} "
                  f"({(time.time()-t0)/step:.2f}s/step)")

    ev = trainer.evaluate(eval_batch)
    print(f"[final] greedy reward {ev['reward_greedy']:.4f} "
          f"(start {r0['reward_greedy']:.4f}) "
          f"exact-match {ev['exact_match']:.3f}")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    RespectScheduler(trainer.params).save(out)
    print(f"[saved] {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
