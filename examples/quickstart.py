#!/usr/bin/env python
"""Quickstart: schedule ResNet50 onto a 4-stage pipelined Edge TPU system.

Runs the full Fig. 1a flow — graph extraction, embedding, PtrNet decode, rho,
post-inference repair — with the three scheduler backends (RESPECT / exact /
commercial-compiler emulation) and reports simulated on-chip inference
runtime for each.

    PYTHONPATH=src python examples/quickstart.py [--model ResNet50] [--stages 4]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import (EDGETPU, RespectScheduler, build_model_graph,  # noqa: E402
                        compiler_partition, evaluate_schedule, exact_dp,
                        validate_monotone)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="ResNet50")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--agent", default="artifacts/respect_agent.npz")
    args = ap.parse_args()

    g = build_model_graph(args.model)
    sys_ = EDGETPU.with_stages(args.stages)
    print(f"model {args.model}: |V|={g.n} deg={g.max_in_degree} "
          f"depth={g.depth} params={g.param_bytes.sum()/2**20:.1f} MiB")

    # --- RESPECT -------------------------------------------------------- #
    agent_path = Path(args.agent)
    if agent_path.exists():
        sched = RespectScheduler.load(agent_path)
        print(f"[agent] loaded {agent_path}")
    else:
        sched = RespectScheduler.init(seed=0)
        print("[agent] untrained weights (run examples/train_respect.py "
              "for the trained agent)")
    t0 = time.perf_counter()
    res = sched.schedule(g, args.stages, sys_, return_timing=True)
    t_rl = time.perf_counter() - t0
    assert validate_monotone(g, res.assignment, args.stages)
    ev_rl = evaluate_schedule(g, res.assignment, sys_)

    # --- exact + compiler baselines ------------------------------------- #
    t0 = time.perf_counter()
    a_exact, _ = exact_dp(g, args.stages, sys_)
    t_exact = time.perf_counter() - t0
    ev_exact = evaluate_schedule(g, a_exact, sys_)

    t0 = time.perf_counter()
    a_comp = compiler_partition(g, args.stages, sys_)
    t_comp = time.perf_counter() - t0
    ev_comp = evaluate_schedule(g, a_comp, sys_)

    print(f"\n{'scheduler':12s} {'solve (ms)':>10s} {'runtime (ms)':>13s} "
          f"{'vs compiler':>12s}")
    base = ev_comp.bottleneck_s
    for name, t, ev in (("compiler", t_comp, ev_comp),
                        ("exact", t_exact, ev_exact),
                        ("RESPECT", t_rl, ev_rl)):
        print(f"{name:12s} {t*1e3:10.2f} {ev.bottleneck_s*1e3:13.3f} "
              f"{base/ev.bottleneck_s:11.2f}x")

    print("\nper-stage parameter placement (RESPECT):")
    for s in range(args.stages):
        mb = ev_rl.stage_params[s] / 2**20
        flag = " (over 8 MiB SRAM!)" if ev_rl.off_cache_bytes[s] > 0 else ""
        print(f"  stage {s}: {int((res.assignment == s).sum()):4d} ops, "
              f"{mb:6.2f} MiB params{flag}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
