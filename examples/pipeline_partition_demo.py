#!/usr/bin/env python
"""RESPECT partitions a pod-scale LM across pipeline stages (the adaptation).

Builds the block-level CompGraph of an assigned architecture at a shape cell,
partitions it with the compiler-emulation / exact / RESPECT schedulers onto a
PodSystem ring, prints the stage map + bottleneck comparison — then executes
a REDUCED version of the winning partition on an actual shard_map pipeline
(8 host devices) and verifies pipelined == sequential outputs.

    PYTHONPATH=src python examples/pipeline_partition_demo.py --arch qwen3-32b
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import sys  # noqa: E402
from pathlib import Path  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SHAPES, get_config, get_smoke_config  # noqa: E402
from repro.core import PodSystem, RespectScheduler  # noqa: E402
from repro.core.partitioner import (partition_model,  # noqa: E402
                                    stage_assignment_to_layers)
from repro.launch.mesh import make_pipeline_mesh  # noqa: E402
from repro.parallel.pipeline import PipelineRunner  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--stages", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    agent = Path("artifacts/respect_agent.npz")
    sched = (RespectScheduler.load(agent) if agent.exists()
             else RespectScheduler.init(seed=0))

    print(f"== partitioning {args.arch} @ {shape.name} into "
          f"{args.stages} stages (PodSystem) ==")
    rows = []
    for method in ("compiler", "list", "exact", "respect"):
        assign, ev, g = partition_model(
            cfg, shape, args.stages, method=method,
            scheduler=sched if method == "respect" else None,
            mesh_slice=64)
        rows.append((method, ev))
        sizes = [int((assign == s).sum()) for s in range(args.stages)]
        print(f"{method:9s} bottleneck={ev.bottleneck_s*1e3:8.2f} ms  "
              f"stage sizes={sizes}  "
              f"stage params GB={[round(p/1e9,1) for p in ev.stage_params]}")
    base = rows[0][1].bottleneck_s
    for method, ev in rows[1:]:
        print(f"  {method} speedup over compiler: "
              f"{base/ev.bottleneck_s:.2f}x")

    # ---- execute a reduced version on a real shard_map pipeline -------- #
    print("\n== executing reduced config on an 8-device shard_map pipeline ==")
    small = get_smoke_config(args.arch)
    if small.block_pattern is not None:
        print("(hybrid pattern: pipeline runner demo uses the dense path)")
        small = get_smoke_config("internlm2-1.8b")
    small = small.scaled(n_layers=8)
    assign, ev, g = partition_model(small, SHAPES["train_4k"], args.stages,
                                    method="exact")
    stages = stage_assignment_to_layers(small, assign)
    if any(len(s) == 0 for s in stages):
        # tiny-model edge case: the cost-optimal partition may leave a stage
        # empty; the SPMD pipeline needs one block per stage, so even-split.
        stages = [list(r) for r in np.array_split(
            np.arange(small.n_layers), args.stages)]
    mesh = make_pipeline_mesh(n_stages=args.stages, data=2, model=1)
    runner = PipelineRunner(small, mesh, stages, n_micro=4, remat=False)
    params = runner.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 16, small.d_model)
                          ).astype(jnp.bfloat16)
    with jax.set_mesh(mesh):
        y_pipe = jax.jit(runner.forward)(params, x)
    y_seq = runner.sequential_forward(params, x)
    err = float(jnp.max(jnp.abs(y_pipe.astype(jnp.float32)
                                - y_seq.astype(jnp.float32))))
    print(f"pipelined vs sequential max |err| = {err:.2e}  "
          f"({'OK' if err < 1e-3 else 'MISMATCH'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
