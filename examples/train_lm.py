#!/usr/bin/env python
"""Train a reduced LM with the production stack on CPU.

Exercises the full training substrate end-to-end on this machine: sharded
train step (grad accumulation, clipping, AdamW, schedule), deterministic
data pipeline, fault-tolerant loop (checkpoint / resume — kill it mid-run
and re-invoke to continue), metrics JSONL.

    PYTHONPATH=src python examples/train_lm.py --arch internlm2-1.8b --steps 50

Any of the ten --arch ids works (the reduced smoke config of that family is
trained); the full configs are for the 256-chip dry-run, not a CPU.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, TrainConfig, get_smoke_config  # noqa: E402
from repro.data import TokenStream  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.models.model import build_model, count_params  # noqa: E402
from repro.runtime import TrainLoop, TrainLoopConfig  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="artifacts/lm_ckpt")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.block_pattern is None:
        cfg = cfg.scaled(n_layers=args.layers)
    model = build_model(cfg, remat=False)
    print(f"[model] {args.arch} (reduced): "
          f"{count_params(model)/1e6:.2f}M params")

    tcfg = TrainConfig(microbatches=2, lr=1e-3, warmup_steps=10,
                       total_steps=args.steps, weight_decay=0.01)
    optimizer = steps_mod.make_optimizer(tcfg)
    train_fn = jax.jit(steps_mod.make_train_fn(model, tcfg, optimizer))

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = optimizer.init(params)

    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=0)

    def batch_fn(step):
        if cfg.family == "audio":
            return {"audio_embed": jnp.zeros(
                        (args.batch, cfg.encoder_seq, cfg.d_model),
                        jnp.bfloat16),
                    **{k: jnp.asarray(v)
                       for k, v in stream.batch_at(step).items()}}
        if cfg.family == "vlm":
            return {"patches": jnp.zeros(
                        (args.batch, cfg.n_patches, cfg.d_model),
                        jnp.bfloat16),
                    **{k: jnp.asarray(v)
                       for k, v in stream.batch_at(step).items()}}
        return {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}

    loop = TrainLoop(
        step_fn=train_fn, batch_fn=batch_fn, params=params,
        opt_state=opt_state,
        config=TrainLoopConfig(total_steps=args.steps,
                               save_every=args.save_every, log_every=5),
        ckpt_dir=Path(args.ckpt_dir) / args.arch,
        metrics_path=f"artifacts/lm_train_{args.arch}.jsonl")
    out = loop.run()
    print(f"[done] {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
